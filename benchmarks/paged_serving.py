"""Benchmark E5 — the TPU adaptation of Fig. 2 at serving granularity:
zero-copy (paged/mapped) vs copy-based (staged) KV admission, on the real
continuous-batching engine with a reduced model (CPU-runnable).

Adds the PREFIX-HEAVY workload: many requests sharing a common system
prompt (plus some exact-duplicate prompts), served with copy-on-write
prefix sharing ON vs OFF — reporting pages shared, prefill tokens saved,
CoW page duplications, and verifying decode outputs are bit-identical to
unshared serving (physical placement never changes results).

Also reports the paged-attention kernel's translation-traffic A/B:
table-resident-in-SMEM (the paper's LLC-on) vs gather-through-HBM (LLC-off),
as modeled data movement per decode step.

``--translation-report`` serves a prefix-heavy workload with translation
tracing ON, then replays the recorded per-decode-step page accesses through
the unified IOMMU front-end under different design points — ``CountingWalk``
(pure hit/miss stats) vs ``Sv39Walk(llc=False/True)`` priced like the
paper's platform — and prints modeled PTW overhead as a % of each decode
step's accelerator runtime: the Fig. 5 claims, measured on the serving hot
path instead of the standalone simulator. It also prints the ADAPTIVE
front-end rows (``translation.adaptive.*``): the same trace with IOTLB
stream prefetching and with the online geometry auto-tuner, including the
configuration the tuner converged to. ``--prefetch``/``--autotune`` arm
those knobs on the served engine itself (see ``--help`` and
``benchmarks/README.md``).

The default benchmark also runs the range-coalescing A/B
(``paged_serving.range.*``): the same continuous-batching workload served
with ``ModelConfig.serve_tlb_ranges`` on vs off must be bit-identical
(ranges change translation accounting only), and the translation report
prints ``translation.range.*`` replay rows (range vs per-page at equal
IOTLB entry count) plus the ``translation.fragmentation.runs_per_seq``
allocator-contiguity summary. ``--tlb-ranges`` sets the coalescing cap.

``--dry-run`` runs a minimal-size fast path (CI smoke).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from pathlib import Path
from typing import List

import jax
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.trace_replay import replay_trace, trace_fragmentation
from repro.configs import get_config, reduce_for_smoke
from repro.configs.paper_soc import PaperSoCConfig
from repro.core.serving.engine import ServingEngine
from repro.core.simulator.platform import H2A
from repro.core.sva.iommu import (IOMMU, AutoTuneConfig, CountingWalk,
                                  PrefetchConfig, Sv39Walk, TLBAutoTuner,
                                  TLBConfig, WalkCacheConfig)
from repro.models import init_params


def _cfg_params():
    cfg = reduce_for_smoke(get_config("llama3.2-1b"))
    return cfg, init_params(cfg, jax.random.key(0))


def _run_engine(mode: str, n_req: int = 6, max_tokens: int = 8):
    cfg, params = _cfg_params()
    eng = ServingEngine(cfg, params, n_slots=3, max_len=64, page_size=8,
                        offload_mode=mode)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for _ in range(n_req):
        eng.submit(rng.integers(0, cfg.vocab_size, size=12).tolist(),
                   max_tokens=max_tokens)
    done = eng.run()
    wall = time.perf_counter() - t0
    return wall, eng.stats(), done


def _prefix_heavy_prompts(n_req: int, vocab: int):
    """A serving mix dominated by a shared system prompt: half the requests
    are EXACT duplicates of one popular prompt (retries / common question —
    these also share the partially-filled tail page, so their first decode
    divergence exercises CoW), a quarter append a distinct user turn, a
    quarter are unrelated."""
    rng = np.random.default_rng(7)
    system = rng.integers(0, vocab, size=24).tolist()   # 3 full pages @ 8
    dup = system + rng.integers(0, vocab, size=5).tolist()
    prompts = []
    for i in range(n_req):
        if i % 4 == 3:
            prompts.append(rng.integers(0, vocab, size=10).tolist())
        elif i % 4 in (1, 2):
            prompts.append(list(dup))
        else:
            prompts.append(system + rng.integers(0, vocab, size=6).tolist())
    return prompts


def _run_prefix_workload(share: bool, n_req: int, max_tokens: int,
                         policy: str = "lru", cap_pages: int = 0):
    cfg, params = _cfg_params()
    cfg = dataclasses.replace(cfg, prefix_cache_policy=policy,
                              prefix_cache_pages=cap_pages)
    eng = ServingEngine(cfg, params, n_slots=4, max_len=64, page_size=8,
                        prefix_sharing=share)
    prompts = _prefix_heavy_prompts(n_req, cfg.vocab_size)
    t0 = time.perf_counter()
    rids = [eng.submit(p, max_tokens=max_tokens) for p in prompts]
    done = eng.run()
    wall = time.perf_counter() - t0
    outs = [done[r].out_tokens for r in rids]
    return wall, eng.stats(), outs


# -------------------------------------------- bursty scheduler A/B workload

# Mixed-length bursty mix tuned so the shared pool is oversubscribed: the
# fixed scheduler must WAIT at admission while continuous lazily over-admits
# and preempts under pressure — the regime where token-budget scheduling
# wins (vLLM/eSurge).
_BURST_LENS = (11, 23, 5, 17, 9, 13)
_BURST_MAXTOKS = (10, 8, 12, 9, 11, 10)
_BURST_POOL = 8          # pages; n_slots*max_pages would be 32 (no pressure)


def _bursty_workload(vocab: int, n_req: int):
    """Bursty Poisson arrivals over mixed-length prompts: inter-arrival
    gaps ~ Poisson(1) cluster several requests onto the same engine step
    (a burst), then leave idle gaps — the arrival pattern continuous
    batching exists for."""
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, vocab, size=n).tolist()
               for n in _BURST_LENS[:n_req]]
    gaps = rng.poisson(1.0, size=n_req)
    gaps[0] = 0
    arrivals = np.cumsum(gaps).tolist()
    return prompts, list(_BURST_MAXTOKS[:n_req]), arrivals


def _run_bursty(scheduler: str, pool_pages, prompts, maxtoks, arrivals):
    """Drive one engine over the bursty arrival schedule, injecting each
    submission between steps at its arrival tick (the engine never sees
    the future). Returns (stats, per-request outputs, steps-to-first-token
    per request, engine steps executed)."""
    cfg, params = _cfg_params()
    eng = ServingEngine(cfg, params, n_slots=4, max_len=64, page_size=8,
                        scheduler=scheduler, pool_pages=pool_pages)
    finished = {}
    rids = [None] * len(prompts)
    order = sorted(range(len(prompts)), key=lambda j: arrivals[j])
    i, clock, n_steps = 0, 0, 0
    while i < len(order) or eng.has_work:
        while i < len(order) and arrivals[order[i]] <= clock:
            j = order[i]
            rids[j] = eng.submit(prompts[j], max_tokens=maxtoks[j])
            i += 1
        if eng.has_work:
            eng.step(finished)
            n_steps += 1
        clock += 1
    outs = [finished[r].out_tokens for r in rids]
    ttft = [finished[r].first_token_step - finished[r].submitted_step
            for r in rids]
    return eng.stats(), outs, ttft, n_steps


def run_scheduler_ab(dry_run: bool = False) -> List[str]:
    """Fixed vs continuous scheduling on the SAME bursty workload at the
    SAME (oversubscribed) page pool: tokens/step, steps-to-first-token,
    preemption counts — with both constrained runs checked bit-identical
    to an unconstrained reference (scheduling policy never changes
    tokens)."""
    n_req = 4 if dry_run else 6
    vocab = reduce_for_smoke(get_config("llama3.2-1b")).vocab_size
    prompts, maxtoks, arrivals = _bursty_workload(vocab, n_req)
    _, ref_outs, _, _ = _run_bursty("fixed", None, prompts, maxtoks,
                                    arrivals)
    rows, tps = [], {}
    identical = True
    for sched in ("fixed", "continuous"):
        s, outs, ttft, n_steps = _run_bursty(sched, _BURST_POOL, prompts,
                                             maxtoks, arrivals)
        identical = identical and outs == ref_outs
        tps[sched] = s["tokens"] / max(n_steps, 1)
        extra = ""
        if sched == "continuous":
            extra = (f" preemptions={s['preemptions']}"
                     f" resumes={s['resumes']}")
        rows.append(
            f"paged_serving.sched.{sched}.tokens_per_step,"
            f"{tps[sched]:.2f},{s['tokens']} decode tokens in {n_steps} "
            f"steps at pool_pages={_BURST_POOL} "
            f"mean_steps_to_first_token={np.mean(ttft):.1f}{extra}")
    rows.append(f"paged_serving.sched.continuous_advantage,"
                f"{100 * (tps['continuous'] / max(tps['fixed'], 1e-9) - 1):.0f},"
                "percent higher tokens/step from token-budget scheduling "
                "under pool pressure (equal pool, equal workload)")
    rows.append(f"paged_serving.sched.bit_identical,{identical},"
                "pool-constrained fixed AND continuous outputs vs the "
                "unconstrained reference (chunked prefill + preempt/resume "
                "never change tokens)")
    return rows


def run_range_ab(dry_run: bool = False, tlb_ranges: int = 8) -> List[str]:
    """Range-coalesced IOTLB entries ON vs OFF over the SAME prefix-heavy
    continuous-batching workload on an oversubscribed pool — admissions,
    CoW divergence, and preempt/resume teardown all exercise the range
    fill/split paths live. Outputs must be bit-identical: ranges change
    translation accounting only, never placement or data movement."""
    n_req, max_tokens = (4, 4) if dry_run else (8, 8)
    outs, stats = {}, {}
    for ranges in (0, tlb_ranges):
        cfg, params = _cfg_params()
        cfg = dataclasses.replace(cfg, serve_tlb_ranges=ranges)
        eng = ServingEngine(cfg, params, n_slots=4, max_len=64, page_size=8,
                            scheduler="continuous", pool_pages=_BURST_POOL,
                            translation_stats=True)
        prompts = _prefix_heavy_prompts(n_req, cfg.vocab_size)
        rids = [eng.submit(p, max_tokens=max_tokens) for p in prompts]
        done = eng.run()
        outs[ranges] = [done[r].out_tokens for r in rids]
        stats[ranges] = eng.stats()
    identical = outs[0] == outs[tlb_ranges]
    s = stats[tlb_ranges]
    rng = s["iommu"].get("range", {})
    return [
        f"paged_serving.range.bit_identical,{identical},"
        f"continuous serving outputs with range-coalesced IOTLB entries "
        f"(ranges={tlb_ranges}) vs per-page — translation accounting "
        f"only, never placement or data movement",
        f"paged_serving.range.coalesced_pages,"
        f"{rng.get('coalesced_pages', 0)},"
        f"pages covered by live range fills (range_entries="
        f"{rng.get('fills', 0)} hits={rng.get('hits', 0)} "
        f"range_splits={rng.get('splits', 0)}; contiguity-hinted "
        f"allocations: run_allocs={s['pool_run_allocs']} "
        f"run_fallbacks={s['pool_run_fallbacks']})"]


# ------------------------------------------ multi-tenant serving A/B
def _run_tenant_scenario(reqs, dep, pool_pages: int):
    """Drive one continuous engine over a scenario trace (benchmarks/
    scenarios.py), injecting each request at its arrival tick under its
    tenant. ``dep=None`` is the untenanted control arm (same TLB
    geometry, tenant labels dropped)."""
    cfg, params = _cfg_params()
    if dep is not None:
        cfg = dep.compile(cfg)
        tenants = dep.tenant_dict(pool_pages)
    else:
        cfg = dataclasses.replace(cfg, serve_tlb_entries=_TENANT_TLB_ENTRIES,
                                  serve_tlb_ways=_TENANT_TLB_WAYS)
        tenants = None
    eng = ServingEngine(cfg, params, n_slots=4, max_len=64, page_size=8,
                        scheduler="continuous", pool_pages=pool_pages,
                        translation_stats=True, tenants=tenants)
    finished = {}
    rids = []
    i, clock = 0, 0
    while i < len(reqs) or eng.has_work:
        while i < len(reqs) and reqs[i].arrival <= clock:
            r = reqs[i]
            rids.append(eng.submit(list(r.prompt), max_tokens=r.max_tokens,
                                   tenant=r.tenant if tenants else None))
            i += 1
        if eng.has_work:
            eng.step(finished)
        clock += 1
    outs = [finished[r].out_tokens for r in rids]
    return outs, eng.stats()


# Tiny serving IOTLB for the tenant A/B: 2 sets x 4 ways, so one bursty
# tenant's working set cannot fit its private ways and the per-tenant
# conflict_misses partition rows come out nonzero even at --dry-run size.
_TENANT_TLB_ENTRIES = 8
_TENANT_TLB_WAYS = 4
_TENANT_POOL = 16


def run_tenant_ab(dry_run: bool = False) -> List[str]:
    """Multi-tenant serving A/B over one seeded scenario trace (see
    benchmarks/scenarios.py): an untenanted control, tenants with a
    SHARED IOTLB, and tenants with way-partitioned private ways — all
    three must produce bit-identical outputs (tenancy changes isolation
    and translation accounting, never tokens). Reports per-tenant
    conflict-miss partition rows and the partitioned-vs-shared hit-rate
    delta, plus the cross-tenant prefix-isolation check on an
    adversarial collision trace."""
    from benchmarks.scenarios import generate
    from repro.configs.deployment import two_tenant_demo

    n_req = 6 if dry_run else 10
    cfg0 = reduce_for_smoke(get_config("llama3.2-1b"))
    reqs = generate("bursty_tenants", ("a", "b"), cfg0.vocab_size,
                    n_req=n_req, seed=5)
    deps = {"untenanted": None,
            "shared": dataclasses.replace(
                two_tenant_demo(partitioned=False, ways=_TENANT_TLB_WAYS),
                tlb_entries=_TENANT_TLB_ENTRIES),
            "partitioned": dataclasses.replace(
                two_tenant_demo(partitioned=True, ways=_TENANT_TLB_WAYS),
                tlb_entries=_TENANT_TLB_ENTRIES)}
    outs, stats = {}, {}
    for arm, dep in deps.items():
        outs[arm], stats[arm] = _run_tenant_scenario(reqs, dep,
                                                     _TENANT_POOL)
    identical = (outs["untenanted"] == outs["shared"]
                 == outs["partitioned"])
    rows = [f"paged_serving.tenant.bit_identical,{identical},"
            "continuous serving outputs untenanted vs two-tenant shared "
            "IOTLB vs way-partitioned — isolation and translation "
            "accounting only, never tokens"]
    part = stats["partitioned"]["tenant"]
    shared = stats["shared"]["tenant"]
    for t in sorted(part):
        tb = part[t].get("tlb", {})
        rows.append(
            f"paged_serving.tenant.{t}.conflict_misses,"
            f"{tb.get('conflict_misses', 0)},"
            f"misses inside the tenant's {part[t]['ways']} private "
            f"ways/set that a shared IOTLB of equal size would have "
            f"absorbed (hits={tb.get('hits', 0)} "
            f"misses={tb.get('misses', 0)} "
            f"pages_used={part[t]['pages_used']} "
            f"quota={part[t]['quota_pages']})")
    for t in sorted(part):
        hp = part[t].get("tlb", {}).get("hit_rate", 0.0)
        hs = shared[t].get("tlb", {}).get("hit_rate", 0.0)
        rows.append(
            f"paged_serving.tenant.{t}.partition_hit_rate,{hp:.3f},"
            f"IOTLB hit rate with private ways vs {hs:.3f} sharing all "
            f"{_TENANT_TLB_WAYS} ways (partitioned-vs-shared A/B, equal "
            f"{_TENANT_TLB_ENTRIES}-entry TLB, equal trace)")
    sch = stats["partitioned"].get("sched", {})
    rows.append(
        f"paged_serving.tenant.preemptions,{sch.get('preemptions', 0)},"
        f"scheduler preemptions under pool+quota pressure in the "
        f"partitioned arm (pool={_TENANT_POOL} pages, quotas from "
        f"pool shares; resumes={sch.get('resumes', 0)})")

    # Adversarial cross-tenant prefix collisions: identical prompts from
    # different tenants must NOT share pages once tenants are on.
    col = generate("adversarial_prefix_collisions", ("a", "b"),
                   cfg0.vocab_size, n_req=n_req, seed=7)
    _, s_open = _run_tenant_scenario(col, None, _TENANT_POOL)
    _, s_iso = _run_tenant_scenario(col, deps["shared"], _TENANT_POOL)
    rows.append(
        f"paged_serving.tenant.collision_pages_shared,"
        f"{s_iso['prefix']['pages_shared']},"
        f"prefix pages shared on the adversarial collision trace WITH "
        f"tenant isolation (untenanted control shares "
        f"{s_open['prefix']['pages_shared']}; the isolated count is "
        f"within-tenant re-use only — cross-tenant hits are impossible "
        f"by construction of the tenant-scoped index)")
    return rows


def run(dry_run: bool = False, tlb_ranges: int = 8) -> List[str]:
    n_req, max_tokens = (4, 4) if dry_run else (6, 8)
    rows = []
    stats = {}
    for mode in ("zero_copy", "copy"):
        wall, s, done = _run_engine(mode, n_req=n_req, max_tokens=max_tokens)
        stats[mode] = (wall, s)
        rows.append(f"paged_serving.{mode},{wall*1e6:.0f},"
                    f"tokens={s['tokens']} prefill_s={s['prefill_s']:.3f} "
                    f"staging_copies={s['staging_copies']} "
                    f"bytes_copied={s['sva']['bytes_copied']}")
    zc, cp = stats["zero_copy"][0], stats["copy"][0]
    rows.append(f"paged_serving.zero_copy_advantage,{100*(1-zc/cp):.1f},"
                "percent wall-time saved (CPU engine; paper Fig.2 analogue)")

    # Fig. 2's actual claim, at serving granularity: ADMISSION bytes moved.
    # zero_copy uploads int32 table entries (the paper's 24 B per 4 KiB
    # page); copy stages the prompt's full KV.
    zs, cs = stats["zero_copy"][1], stats["copy"][1]
    zc_admit = zs["admit_table_bytes"]
    cp_admit = cs["sva"]["bytes_copied"]
    rows.append(f"paged_serving.zero_copy_admission_bytes,{zc_admit},"
                f"int32 table entries only "
                f"({zs['sva']['table_entries_written']} entries written)")
    rows.append(f"paged_serving.copy_admission_bytes,{cp_admit},"
                "full KV staged per admitted prompt")
    rows.append(f"paged_serving.admission_bytes_ratio,"
                f"{cp_admit/max(zc_admit,1):.1f},x less admission traffic "
                "with mapped pages (Fig.2 analogue)")
    # Decode-path translation maintenance: delta vs full table uploads.
    rows.append(f"paged_serving.delta_table_upload_bytes,"
                f"{zs['table_upload_bytes']},"
                f"full={zs['table_uploads_full']} "
                f"delta={zs['table_uploads_delta']} "
                f"rows={zs['table_rows_uploaded']} (zero_copy)")
    rows.append(f"paged_serving.full_table_upload_bytes,"
                f"{cs['table_upload_bytes']},"
                f"full re-upload every step x{cs['table_uploads_full']} (copy)")

    # ------------------------------------------ prefix-heavy CoW workload
    pn = 4 if dry_run else 12
    w_on, s_on, out_on = _run_prefix_workload(True, pn, max_tokens)
    w_off, s_off, out_off = _run_prefix_workload(False, pn, max_tokens)
    # Token-identical on this platform (asserted strictly in
    # tests/test_sva_serving.py); reported rather than asserted here since
    # the shared path uses a different (dense) prefill attention whose
    # argmax is not formally guaranteed across BLAS/backends.
    identical = out_on == out_off
    pf = s_on["prefix"]
    rows.append(f"paged_serving.prefix_pages_shared,{pf['pages_shared']},"
                f"hits={pf['hits']} misses={pf['misses']} "
                f"steals={pf['steals']} evictions={pf['evictions']} "
                f"(token-identical to unshared: {identical})")
    rows.append(f"paged_serving.prefill_tokens_saved,"
                f"{s_on['prefill_tokens_saved']},"
                f"prompt tokens NOT recomputed at admission "
                f"(shared_admissions={s_on['shared_admissions']}; "
                f"unshared baseline saves {s_off['prefill_tokens_saved']})")
    rows.append(f"paged_serving.cow_page_copies,{s_on['cow_page_copies']},"
                "device page duplications on write-into-shared-page "
                "(one page of KV per layer vs re-prefilling the prefix)")
    rows.append(f"paged_serving.prefix_prefill_s,"
                f"{s_on['prefill_s']*1e3:.1f},ms prefill with sharing "
                f"(vs {s_off['prefill_s']*1e3:.1f} ms unshared; wall "
                f"{w_on*1e3:.0f} vs {w_off*1e3:.0f} ms). NOTE: at smoke "
                "scale wall time is dominated by the extra jit traces and "
                "the dense prefix-context attention, not the saved tokens; "
                "the scale-relevant win is prefill_tokens_saved")

    # -------------------------- prefix-cache eviction-policy design space
    # Same prefix-heavy mix under a tight warm-cache cap (forces eviction
    # pressure): recency (lru) vs frequency (lfu) — frequency should keep
    # the popular system prompt resident while one-off prompts churn.
    cap = 4
    for policy in ("lru", "lfu"):
        _, sp, _ = _run_prefix_workload(True, pn, max_tokens,
                                        policy=policy, cap_pages=cap)
        ppf = sp["prefix"]
        rows.append(
            f"paged_serving.prefix_policy.{policy},{ppf['hits']},"
            f"admission hits under a {cap}-page warm-cache cap "
            f"(evictions={ppf['evictions']} "
            f"tokens_saved={sp['prefill_tokens_saved']} "
            f"cached_pages={ppf['cached_pages']})")

    # translation-traffic A/B per decode step (modeled bytes):
    cfg = get_config("qwen2-7b")
    B, L, page = 128, 32768, 64
    n_pages = L // page
    kv_layers = cfg.n_layers
    kv_bytes = 2 * B * L * cfg.n_kv_heads * cfg.d_head * 2 * kv_layers
    table_bytes = B * n_pages * 4 * kv_layers
    rows.append(f"paged_serving.table_smem_bytes,{table_bytes},"
                "block tables scalar-prefetched once per step (LLC-on analogue)")
    rows.append(f"paged_serving.table_hbm_gather_bytes,{kv_bytes},"
                "extra pool copy when translations resolve via HBM gather "
                "(LLC-off analogue)")
    rows.append(f"paged_serving.translation_traffic_ratio,"
                f"{kv_bytes/max(table_bytes,1):.0f},x less traffic with "
                "SMEM-resident tables (qwen2-7b decode_32k)")

    # ------------------------------ scheduler A/B on the bursty workload
    rows += run_scheduler_ab(dry_run)
    # ------------------- range-coalesced IOTLB on/off bit-identity check
    if tlb_ranges:
        rows += run_range_ab(dry_run, tlb_ranges=tlb_ranges)
    return rows


# ------------------------------------------------------ translation report

def _replay(trace, walk_model, tlb: TLBConfig, kv_bytes_per_token: int,
            compute_per_token: float, soc: PaperSoCConfig, dram_latency: int):
    """Feed a recorded serving translation trace through an IOMMU design
    point (the shared ``trace_replay`` cost model). Returns (iommu,
    per-step list of (ptw_cycles, step_cycles)) in accelerator cycles."""
    iommu = IOMMU(walk_model=walk_model, tlb=tlb)
    return iommu, replay_trace(trace, iommu, kv_bytes_per_token,
                               compute_per_token, soc, dram_latency)


def _range_report_rows(trace, mk_off, soc, kv_tok, compute_per_token,
                       dram_latency, tlb_ranges, off_iommu, off_steps,
                       off_pcts) -> List[str]:
    """Range-coalesced IOTLB entries (SPARTA analogue) on the recorded
    trace: same 4-entry IOTLB, but one entry may cover a physically
    contiguous run of up to ``tlb_ranges`` pages — the payoff of the
    contiguity-aware allocator, priced at EQUAL entry count against the
    per-page ``llc_off`` baseline. Plus the allocator-side fragmentation
    summary (runs per admitted sequence) the coalescer depends on."""
    pct = lambda p, t: 100.0 * p / max(t, 1e-9)
    rng_iommu = IOMMU(walk_model=mk_off(),
                      tlb=TLBConfig(soc.iotlb_entries, "lru",
                                    ranges=tlb_ranges))
    rng_steps = replay_trace(trace, rng_iommu, kv_tok, compute_per_token,
                             soc, dram_latency)
    rng_pcts = [pct(p, t) for p, t in rng_steps]
    rt, ot = rng_iommu.tlb.stats, off_iommu.tlb.stats
    rio = rng_iommu.stats()["range"]
    frag = trace_fragmentation(trace)
    return [
        f"translation.range.ptw_pct.mean,{np.mean(rng_pcts):.1f},"
        f"demand PTW% with range-coalesced entries (ranges={tlb_ranges}) "
        f"on the {soc.iotlb_entries}-entry IOTLB, no LLC (per-page: "
        f"{np.mean(off_pcts):.1f}%)",
        f"translation.range.demand_misses,{rt.misses},"
        f"demand IOTLB misses vs per-page {ot.misses} at equal entry "
        f"count (range_entries={rio['fills']} range_hits={rio['hits']} "
        f"coalesced_pages={rio['coalesced_pages']} "
        f"range_splits={rio['splits']})",
        f"translation.range.demand_ptw_cycles,"
        f"{sum(p for p, _ in rng_steps):.1f},"
        f"vs per-page {sum(p for p, _ in off_steps):.1f} "
        f"(one walk fills a whole run; neighbours hit the range)",
        f"translation.fragmentation.runs_per_seq,"
        f"{frag['runs_per_seq']:.2f},"
        f"physically contiguous runs per admitted sequence "
        f"({frag['runs']} runs / {frag['sequences']} sequences over "
        f"{frag['pages']} freshly allocated pages; "
        f"mean_run_pages={frag['mean_run_pages']:.2f}; 1.0 = every "
        f"admission one run)"]


def run_translation_report(dry_run: bool = False,
                           dram_latency: int = 200,
                           prefetch_policy: str = "none",
                           prefetch_degree: int = 2,
                           prefetch_distance: int = 4,
                           autotune: int = 0,
                           scheduler: str = "fixed",
                           tlb_ranges: int = 8) -> List[str]:
    """Fig. 5 on the serving hot path: serve a prefix-heavy workload with
    translation tracing, then price the recorded per-decode-step page
    accesses under CountingWalk vs Sv39Walk(llc=False/True) behind the
    paper's 4-entry IOTLB — plus the ADAPTIVE front-end rows (IOTLB
    prefetching and online geometry auto-tuning on the same trace, and the
    configuration the tuner converged to). The ``prefetch_*`` / ``autotune``
    arguments arm the adaptive knobs on the SERVED engine itself
    (``ModelConfig.serve_tlb_prefetch_* / serve_tlb_autotune``), so the
    live-TLB row reflects them end-to-end; the default leaves every knob
    off and the pre-existing report rows bit-identical.

    ``scheduler="continuous"`` serves the SAME workload through the
    continuous-batching scheduler over an oversubscribed page pool, so
    the recorded trace bears ``("preempt", ...)`` / ``("resume", ...)``
    annotations around real ASID teardown/re-mapping — exercising the
    replay path on preemption-bearing traces."""
    n_req, max_tokens = (4, 4) if dry_run else (10, 10)
    cfg, params = _cfg_params()
    cfg = dataclasses.replace(
        cfg, serve_tlb_prefetch_policy=prefetch_policy,
        serve_tlb_prefetch_degree=prefetch_degree,
        serve_tlb_prefetch_distance=prefetch_distance,
        serve_tlb_autotune=autotune)
    pool = _BURST_POOL if scheduler == "continuous" else None
    eng = ServingEngine(cfg, params, n_slots=4, max_len=64, page_size=8,
                        record_translation_trace=True,
                        scheduler=scheduler, pool_pages=pool)
    for p in _prefix_heavy_prompts(n_req, cfg.vocab_size):
        eng.submit(p, max_tokens=max_tokens)
    eng.run()
    trace = eng.translation_trace
    n_steps = sum(1 for ev in trace if ev[0] == "step")

    soc = PaperSoCConfig()
    kv_tok = eng.mgr.kv_bytes_per_token
    n_attn = sum(1 for k in cfg.layer_kinds() if "attn" in k)
    # decode attention: ~4 flops per KV token per head-dim per layer (qk+av)
    compute_per_token = 4 * cfg.n_heads * cfg.d_head * n_attn / soc.n_pes

    rows = [f"translation.trace.steps,{n_steps},"
            f"decode steps recorded ({len(trace)} events; "
            f"kv_bytes_per_token={kv_tok})"]
    if scheduler == "continuous":
        n_pre = sum(1 for ev in trace if ev[0] == "preempt")
        n_res = sum(1 for ev in trace if ev[0] == "resume")
        rows.append(f"translation.trace.preemptions,{n_pre},"
                    f"preempt/resume annotations in the continuous trace "
                    f"(resumes={n_res}; pool_pages={pool}) — replayed "
                    f"through every design point below")
    live = eng.stats()["tlb"]
    rows.append(f"translation.live_tlb_hit_rate,{live['hit_rate']},"
                f"serving IOMMU (4096-entry CountingWalk) on live traffic: "
                f"hits={live['hits']} walks={live['walks']}")

    def replay(model_factory, tlb_entries, ways=0):
        return _replay(trace, model_factory(),
                       TLBConfig(tlb_entries, "lru", ways=ways),
                       kv_tok, compute_per_token, soc, dram_latency)

    counting, _ = replay(CountingWalk, soc.iotlb_entries)
    cstats = counting.stats()["tlb"]
    rows.append(f"translation.iotlb_hit_rate,{cstats['hit_rate']},"
                f"paper's {soc.iotlb_entries}-entry IOTLB replaying the "
                f"same trace: walks={cstats['walks']} (CountingWalk)")
    # Set-associative geometry on the same trace (Kim et al. axis 2): a
    # constrained 4-entry IOTLB trades hits for conflict misses.
    for ways in (1, 2):
        sa, _ = replay(CountingWalk, soc.iotlb_entries, ways=ways)
        ss = sa.stats()["tlb"]
        rows.append(f"translation.iotlb_hit_rate.ways{ways},"
                    f"{ss['hit_rate']},{ways}-way {soc.iotlb_entries}-entry "
                    f"IOTLB: walks={ss['walks']} "
                    f"conflict_misses={ss['conflict_misses']} "
                    f"(fully assoc: {cstats['hit_rate']})")

    mk_off = lambda: Sv39Walk(levels=soc.ptw_levels,
                              dram_access_cycles=dram_latency
                              + soc.dram_base_latency,
                              llc=False, to_accel=H2A)
    mk_on = lambda: Sv39Walk(levels=soc.ptw_levels,
                             dram_access_cycles=dram_latency
                             + soc.dram_base_latency,
                             llc=True, to_accel=H2A)
    off_iommu, off_steps = replay(mk_off, soc.iotlb_entries)
    _, on_steps = replay(mk_on, soc.iotlb_entries)

    pct = lambda p, t: 100.0 * p / max(t, 1e-9)
    for i, ((po, to), (pl, tl)) in enumerate(zip(off_steps, on_steps)):
        rows.append(f"translation.step.{i:03d},{pct(po, to):.1f},"
                    f"% of decode-step runtime spent in PTW, LLC off "
                    f"(LLC on: {pct(pl, tl):.2f}%)")
    off_pcts = [pct(p, t) for p, t in off_steps]
    on_pcts = [pct(p, t) for p, t in on_steps]
    rows.append(f"translation.ptw_pct.llc_off.mean,"
                f"{np.mean(off_pcts):.1f},paper Fig.4/5 band: 4.2-17.6% "
                "(serving gathers translate EVERY page each step — no "
                "tile-level reuse, so a 4-entry IOTLB thrashes)")
    rows.append(f"translation.ptw_pct.llc_off.max,{max(off_pcts):.1f},"
                "worst decode step")
    rows.append(f"translation.ptw_pct.llc_on.mean,{np.mean(on_pcts):.2f},"
                "paper: 0.4-0.7% with LLC-resident PTEs")
    rows.append(f"translation.ptw_pct.llc_on.max,{max(on_pcts):.2f},"
                "worst decode step")
    rows.append(f"translation.claim.llc_reduction,"
                f"{np.mean(off_pcts)/max(np.mean(on_pcts), 1e-9):.0f},"
                "x lower PTW share of decode runtime with the shared LLC "
                "(paper Fig.5: ~15x walk-latency reduction)")

    # Design-space row (Kim et al.): the serving-sized TLB makes the walker
    # model irrelevant — translation maintenance becomes delta uploads.
    _, big_off = replay(mk_off, 4096)
    big = [pct(p, t) for p, t in big_off]
    rows.append(f"translation.ptw_pct.llc_off.tlb4096.mean,"
                f"{np.mean(big):.2f},same trace, serving-sized TLB: "
                "cold-miss walks only (design-space axis: IOTLB size)")
    # Walk-cache axis: a 16-entry non-leaf PTE cache on the walker cuts
    # every miss from 3 sequential DRAM accesses to ~1 without any LLC.
    mk_wc = lambda: Sv39Walk(levels=soc.ptw_levels,
                             dram_access_cycles=dram_latency
                             + soc.dram_base_latency,
                             llc=False, to_accel=H2A,
                             walk_cache=WalkCacheConfig(16))
    wc_iommu, wc_steps = replay(mk_wc, soc.iotlb_entries)
    wcp = [pct(p, t) for p, t in wc_steps]
    wc_stats = wc_iommu.stats()["walk"]["walk_cache"]
    rows.append(f"translation.ptw_pct.llc_off.walkcache16.mean,"
                f"{np.mean(wcp):.2f},same 4-entry IOTLB + 16-entry walk "
                f"cache, no LLC (off: {np.mean(off_pcts):.1f}%; "
                f"wc hits={wc_stats['hits']} misses={wc_stats['misses']}) "
                "— full grid: benchmarks/tlb_sweep.py")

    # --------------------- range-coalesced IOTLB entries (SPARTA analogue)
    # Same trace, same 4-entry IOTLB, but one entry may cover a physically
    # contiguous run of up to ``tlb_ranges`` pages — the payoff of the
    # contiguity-aware allocator, priced at EQUAL entry count against the
    # per-page llc_off baseline above.
    if tlb_ranges:
        rows += _range_report_rows(trace, mk_off, soc, kv_tok,
                                   compute_per_token, dram_latency,
                                   tlb_ranges, off_iommu, off_steps,
                                   off_pcts)

    # ---------------------------------------- adaptive front-end replays
    # IOTLB prefetching (Kurth et al.): stream-detected walks issued ahead
    # of the demand gathers. Demand PTW% is what prefetch lowers — timely
    # prefetched hits cost the demand path nothing, late ones pay the full
    # walk (conservative).
    def replay_pf(tlb_entries, pf):
        iommu = IOMMU(walk_model=mk_off(),
                      tlb=TLBConfig(tlb_entries, "lru"), prefetch=pf)
        steps = replay_trace(trace, iommu, kv_tok, compute_per_token, soc,
                             dram_latency)
        return iommu, [pct(p, t) for p, t in steps]

    # Run-ahead distance is capacity-bounded: 2 on the 4-entry hardware
    # IOTLB (deeper run-ahead evicts its own unused fills), deep on the
    # serving-sized TLB.
    pf_iommu, pf_pcts = replay_pf(soc.iotlb_entries,
                                  PrefetchConfig("stream", degree=2,
                                                 distance=2))
    ps = pf_iommu.stats()["walk"]["prefetch"]
    rows.append(f"translation.adaptive.prefetch_stream.mean,"
                f"{np.mean(pf_pcts):.1f},demand PTW% with stream prefetch "
                f"on the {soc.iotlb_entries}-entry IOTLB, no LLC (static: "
                f"{np.mean(off_pcts):.1f}%; issued={ps['issued']} "
                f"useful={ps['useful']} late={ps['late']})")
    pf_big_iommu, pf_big = replay_pf(4096, PrefetchConfig("stream", degree=4,
                                                          distance=8))
    ps_big = pf_big_iommu.stats()["walk"]["prefetch"]
    rows.append(f"translation.adaptive.prefetch_stream.tlb4096.mean,"
                f"{np.mean(pf_big):.2f},stream prefetch + serving-sized "
                f"TLB: cold misses prefetched ahead too (static 4096: "
                f"{np.mean(big):.2f}%; useful={ps_big['useful']} "
                f"late={ps_big['late']})")
    # Online geometry auto-tuning on the same trace: explores a 4->64
    # entries ladder window by window and settles on the live best — the
    # adaptive replacement for tlb_sweep.py's static per-deployment pick.
    tune_iommu = IOMMU(walk_model=mk_off(), tlb=TLBConfig(4, "lru"))
    tuner = TLBAutoTuner(tune_iommu, AutoTuneConfig(
        interval_steps=1 if dry_run else 4,
        candidates=(TLBConfig(4, "lru"), TLBConfig(16, "lru"),
                    TLBConfig(64, "lru"))))
    tune_steps = replay_trace(trace, tune_iommu, kv_tok, compute_per_token,
                              soc, dram_latency, tuner=tuner)
    tp = [pct(p, t) for p, t in tune_steps]
    ts = tuner.stats()
    cur = ts["current"]
    rows.append(f"translation.adaptive.autotune.mean,{np.mean(tp):.1f},"
                f"demand PTW% while auto-tuning a 4->64 entries ladder "
                f"(static 4-entry: {np.mean(off_pcts):.1f}%; "
                f"switches={ts['switches']} windows={ts['windows']})")
    rows.append(f"translation.adaptive.autotune.converged,"
                f"{cur['n_entries']},converged IOTLB geometry "
                f"e{cur['n_entries']}.w{cur['ways']}.{cur['policy']} "
                f"(phase={ts['phase']}; explored={ts['explored']})")
    # The served engine's own adaptive state (nonzero only when the CLI
    # armed the knobs end-to-end via ModelConfig.serve_tlb_*).
    mstats = eng.stats()
    io = mstats["iommu"]
    if "autotune" in io:
        at = io["autotune"]
        rows.append(f"translation.engine.autotune.converged,"
                    f"{io['tlb_entries']},live serving TLB converged to "
                    f"e{io['tlb_entries']}.w{io['tlb_ways']}."
                    f"{io['tlb_policy']} (phase={at['phase']} "
                    f"switches={at['switches']} windows={at['windows']})")
    if cfg.serve_tlb_prefetch_policy != "none":
        lt = mstats["tlb"]
        rows.append(f"translation.engine.prefetch.useful,"
                    f"{lt['prefetch_useful']},live serving IOMMU prefetch "
                    f"({cfg.serve_tlb_prefetch_policy}): "
                    f"issued={lt['prefetch_issued']} "
                    f"late={lt['prefetch_late']}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(
        description="Paged-serving benchmark: zero-copy vs staged "
                    "admission, CoW prefix sharing, and the translation "
                    "front-end (static IOTLB geometry via "
                    "ModelConfig.serve_tlb_{entries,ways,policy}, adaptive "
                    "via the --prefetch*/--autotune flags below).",
        epilog="The translation report always prints the adaptive replay "
               "rows (translation.adaptive.*: stream prefetch + online "
               "geometry auto-tuning on the recorded trace, and the "
               "configuration the tuner converged to); --prefetch/"
               "--autotune additionally arm the knobs on the SERVED engine "
               "(ModelConfig.serve_tlb_prefetch_* / serve_tlb_autotune). "
               "Methodology, trace contract, and CSV columns: "
               "benchmarks/README.md; full geometry grid: "
               "benchmarks/tlb_sweep.py.")
    ap.add_argument("--dry-run", action="store_true",
                    help="minimal sizes (CI smoke path)")
    ap.add_argument("--tenants", action="store_true",
                    help="run the multi-tenant serving A/B instead: "
                         "untenanted vs two-tenant shared-IOTLB vs "
                         "way-partitioned over one seeded scenario trace "
                         "(benchmarks/scenarios.py) — bit-identity row, "
                         "per-tenant conflict_misses partition rows, "
                         "partitioned-vs-shared hit-rate A/B, and the "
                         "cross-tenant prefix-collision isolation row "
                         "(configs/deployment.py describes the tenants)")
    ap.add_argument("--translation-report", action="store_true",
                    help="replay the serving translation trace through "
                         "Sv39Walk(llc on/off): per-decode-step PTW %%, "
                         "plus the adaptive prefetch/auto-tune rows")
    ap.add_argument("--dram-latency", type=int, default=200,
                    help="AXI delayer setting for the Sv39 walk replay")
    ap.add_argument("--prefetch", default="none",
                    choices=("none", "next_page", "stream"),
                    help="arm the served engine's IOTLB prefetcher "
                         "(ModelConfig.serve_tlb_prefetch_policy)")
    ap.add_argument("--prefetch-degree", type=int, default=2,
                    help="prefetch fills issued per trigger")
    ap.add_argument("--prefetch-distance", type=int, default=4,
                    help="stream run-ahead distance in pages")
    ap.add_argument("--autotune", type=int, default=0, metavar="STEPS",
                    help="auto-tune the served engine's TLB geometry with "
                         "this measurement window in decode steps "
                         "(ModelConfig.serve_tlb_autotune; 0 = off)")
    ap.add_argument("--scheduler", default="fixed",
                    choices=("fixed", "continuous"),
                    help="scheduler for the --translation-report serving "
                         "run; 'continuous' serves over an oversubscribed "
                         "pool so the recorded trace bears preempt/resume "
                         "events (the default benchmark always runs the "
                         "fixed-vs-continuous A/B)")
    ap.add_argument("--tlb-ranges", type=int, default=8,
                    help="max pages per range-coalesced IOTLB entry (>= 2) "
                         "for the range on/off serving A/B and the "
                         "translation.range.* replay rows "
                         "(ModelConfig.serve_tlb_ranges on the A/B engine; "
                         "0 disables the range rows)")
    args = ap.parse_args()
    if args.tenants:
        print("\n".join(run_tenant_ab(dry_run=args.dry_run)))
    elif args.translation_report:
        print("\n".join(run_translation_report(
            dry_run=args.dry_run, dram_latency=args.dram_latency,
            prefetch_policy=args.prefetch,
            prefetch_degree=args.prefetch_degree,
            prefetch_distance=args.prefetch_distance,
            autotune=args.autotune, scheduler=args.scheduler,
            tlb_ranges=args.tlb_ranges)))
    else:
        print("\n".join(run(dry_run=args.dry_run,
                            tlb_ranges=args.tlb_ranges)))
