"""Generate EXPERIMENTS.md from the dry-run artifacts + simulator benches.

  PYTHONPATH=src python -m benchmarks.report > EXPERIMENTS.md
"""
from __future__ import annotations

import json
import pathlib

import numpy as np

from benchmarks import roofline as rf
from repro.core.simulator.paper_targets import CLAIMS, TABLE2
from repro.core.simulator.run import (host_copy_cycles, host_map_cycles,
                                      offload_breakdown, simulate_kernel)

ART = pathlib.Path("results/dryrun")
LATS = (200, 600, 1000)


def section_paper_validation(out):
    out.append("## §Paper-validation — the faithful reproduction\n")
    out.append("Simulator (`src/repro/core/simulator`) vs the paper's "
               "published numbers. Structural model: double-buffered tile "
               "execution, 4-entry IOTLB, 3-level PTW, PTE-resident LLC, "
               "DMA bypass; per-kernel schedule constants calibrated once "
               "against Table II (`calibrate.py`) and frozen.\n")
    errs = []
    out.append("\n### Table II (36 cells, accelerator cycles)\n")
    out.append("| kernel | config | 200 | 600 | 1000 |")
    out.append("|---|---|---|---|---|")
    for k, tgt in TABLE2.items():
        for cfg in ("baseline", "iommu", "iommu_llc"):
            cells = []
            for lat in LATS:
                sim = simulate_kernel(k, cfg, lat).total
                ref = tgt[cfg][lat]
                errs.append(abs(sim - ref) / ref)
                cells.append(f"{sim:.3g} vs {ref:.3g} ({100*(sim-ref)/ref:+.1f}%)")
            out.append(f"| {k} | {cfg} | " + " | ".join(cells) + " |")
    out.append(f"\n**Mean \\|err\\| = {100*np.mean(errs):.2f}%, "
               f"max = {100*np.max(errs):.2f}%** across all 36 cells.\n")

    out.append("### Headline claims\n")
    out.append("| claim | paper | simulated |")
    out.append("|---|---|---|")
    g200 = 100 * (simulate_kernel("gemm", "iommu", 200).total
                  / simulate_kernel("gemm", "baseline", 200).total - 1)
    g1000 = 100 * (simulate_kernel("gemm", "iommu", 1000).total
                   / simulate_kernel("gemm", "baseline", 1000).total - 1)
    out.append(f"| gemm IOVA-translation overhead, low->high latency "
               f"| 4.2% -> 17.6% | {g200:.1f}% -> {g1000:.1f}% |")
    worst = max(simulate_kernel(k, "iommu_llc", lat).total
                / simulate_kernel(k, "baseline", lat).total - 1
                for k in TABLE2 for lat in LATS)
    out.append(f"| IOMMU+LLC overhead, all kernels | < 2% | "
               f"max {100*worst:.2f}% |")
    pn = [simulate_kernel('axpy', 'iommu', l).avg_ptw_host_cycles for l in LATS]
    pl = [simulate_kernel('axpy', 'iommu_llc', l).avg_ptw_host_cycles
          for l in LATS]
    pi = [simulate_kernel('axpy', 'iommu_llc', l,
                          host_interference=0.028).avg_ptw_host_cycles
          for l in LATS]
    out.append(f"| LLC cuts avg PTW time | 15x | "
               f"{np.mean(pn)/np.mean(pl):.1f}x |")
    out.append(f"| PTW with LLC at L=1000 | <= 200 cyc | {max(pl):.0f} cyc |")
    out.append(f"| host interference slows PTW | ~20% | "
               f"+{100*(np.mean(pi)/np.mean(pl)-1):.0f}% |")
    nb = 3 * 32768 * 4
    out.append(f"| copy time growth 200->1000 | 3.4x | "
               f"{host_copy_cycles(nb,1000)/host_copy_cycles(nb,200):.2f}x |")
    out.append(f"| map time growth 200->1000 | 2.1x | "
               f"{host_map_cycles(nb,1000)/host_map_cycles(nb,200):.2f}x |")
    cb = offload_breakdown("copy", 32768, 200).total
    zb = offload_breakdown("zero_copy", 32768, 200).total
    hb = offload_breakdown("host", 32768, 200).total
    out.append(f"| zero-copy vs copy-based offload (axpy) | 47% faster | "
               f"{100*(1-zb/cb):.1f}% faster |")
    out.append(f"| copy-based offload can lose to host exec | yes | "
               f"copy {cb:.3g} > host {hb:.3g} cycles |")
    out.append("\nDeviation notes: the simulator's PTE-residency model gives "
               "a ~20x LLC PTW speedup vs the paper's 15x average (our LLC "
               "model is slightly more optimistic; bounded by the <=200-cycle "
               "and Table II constraints, which both hold). IOMMU+LLC "
               "overhead reaches 3.1% on one mergesort cell vs the paper's "
               "<2% blanket claim — the cost of fitting Fig. 5 and Table II "
               "with one parameter set.\n")


def section_dryrun(out):
    out.append("\n## §Dry-run — 40 cells x {16x16, 2x16x16} meshes\n")
    out.append("Every (architecture x shape) cell lowered AND compiled with "
               "`jax.jit(...).lower().compile()` on placeholder meshes "
               "(512 host devices), per-device `memory_analysis()` and "
               "`cost_analysis()` recorded. `SKIP` rows are the documented "
               "long_500k full-attention exclusions (DESIGN.md §7).\n")
    for pod, name in (("pod1", "single-pod 16x16 (256 chips)"),
                      ("pod2", "multi-pod 2x16x16 (512 chips)")):
        out.append(f"\n### {name}\n")
        out.append("| arch | shape | compile s | peak GiB/dev | fits v5e "
                   "| HLO flops/dev | coll bytes/dev |")
        out.append("|---|---|---|---|---|---|---|")
        n_ok = n_skip = 0
        for p in sorted(ART.glob(f"*__{pod}.json")):
            art = json.loads(p.read_text())
            if art.get("skipped"):
                arch, shape = art["arch"], art["shape"]
                out.append(f"| {arch} | {shape} | SKIP | — | — | — | — |")
                n_skip += 1
                continue
            if art.get("error"):
                out.append(f"| {art['arch']} | {art['shape']} | ERROR | — | — | — | — |")
                continue
            n_ok += 1
            peak = art["memory"]["peak_bytes_per_device"] / 2**30
            fits = "yes" if peak <= 16 else "**no**"
            out.append(
                f"| {art['arch']} | {art['shape']['name']} | "
                f"{art['compile_s']:.1f} | {peak:.2f} | {fits} | "
                f"{art['cost']['flops']:.3g} | "
                f"{art['collective_link_bytes']:.3g} |")
        out.append(f"\ncompiled OK: {n_ok}, documented skips: {n_skip}\n")
    out.append(
        "\nCells marked **no** exceed a 16 GiB v5e HBM: kimi-k2-1t "
        "training needs >= 4 pods (1T params x 14 bytes AdamW state "
        "~= 55 GiB/chip fully sharded on 256), jamba-398B and "
        "llama-vision-90B training likewise on one pod; their dry-runs "
        "still prove the sharding is coherent and give the roofline "
        "terms. All serve cells fit except kimi decode/prefill "
        "(2 TB bf16 weights -> 2+ pods).\n")


def section_roofline(out):
    out.append("\n## §Roofline — per (arch x shape), single-pod\n")
    out.append("v5e terms (197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link); "
               "`compute = FLOPs/peak`, `memory = bytes/HBM_bw`, "
               "`collective = link bytes/ICI_bw` (all-reduce counted 2x). "
               "Scan-undercount corrected by unrolled 1/2-block "
               "differencing (DESIGN.md §6). MODEL/HLO = 6ND-style useful "
               "FLOPs over compiled FLOPs; `roofline frac` = "
               "MODEL_FLOPS/peak vs the dominant term (the score).\n")
    out.append("Caveats: (1) XLA CPU promotes bf16 dots to f32, so "
               "HLO bytes/collective bytes are ~2x a TPU execution — terms "
               "are conservative upper bounds, consistent across "
               "before/after comparisons; (2) `bytes accessed` counts every "
               "op's operands, overstating HBM traffic where ops fuse.\n\n")
    out.append(rf.markdown_table("pod1"))
    cells = rf.load_all("pod1")
    if cells:
        worst = min(cells, key=lambda c: c["roofline_fraction"])
        coll = max(cells, key=lambda c: c["t_collective_s"]
                   / max(max(c["t_compute_s"], c["t_memory_s"]), 1e-12))
        out.append(f"\nBottleneck summary: "
                   f"{sum(1 for c in cells if c['bottleneck']=='memory')} "
                   f"memory-bound, "
                   f"{sum(1 for c in cells if c['bottleneck']=='collective')} "
                   f"collective-bound, "
                   f"{sum(1 for c in cells if c['bottleneck']=='compute')} "
                   f"compute-bound cells.\n")


PERF = r"""
## §Perf — hypothesis -> change -> measure -> validate

The three hillclimbed cells (worst fraction / most collective-bound / most
paper-representative) and the iteration log. The paper-faithful baseline
(v0, `results/dryrun_v0`) and the optimized system are recorded separately;
all numbers are per-device dry-run terms on the single-pod mesh.

### Cell A — llama3.2-1b train_4k (worst early fraction; memory-bound)

| iter | hypothesis | change | before -> after | verdict |
|---|---|---|---|---|
| A1 | backward holds every flash-attention score block (inner-scan residuals), dominating temp memory | FlashAttention-style custom VJP saving only (q,k,v,out,lse); blockwise recompute in bwd | grad temp 18.4 GiB -> 8-10 GiB (flash-only probe: 7.9 GiB -> <1 GiB) | **confirmed** |
| A2 | rwkv/mamba/xent scan bodies stash chunk residuals | jax.checkpoint on inner scan bodies | step peak 18.7 -> 12.9 GiB/dev | **confirmed** |
| A3 | XLA replicates q/k/v heads (SPMD gives up on GQA reshape): 4x activation memory | explicit head-sharding constraints + pre-repeated KV | no peak change on its own (masked by A4 issue) | partially confirmed |
| A4 | the remat-saved block-boundary x (and an XLA f32 copy of its stack) dominates | ZeRO-R: shard saved activations' d_model over 'model' (one extra all-gather/block) | peak 12.9 -> **4.7 GiB/dev**; collective 5.8e9 -> 1.1e10 B (accepted trade) | **confirmed** |

Cell A net: **18.7 -> 4.7 GiB/dev** (4.0x), making llama-1b train_4k fit a
single v5e with margin; memory term (bytes accessed) now dominated by fp32
attention softmax + hoisted masks (next lever, not taken: bf16 scores).

### Cell B — qwen2-7b decode_32k (paper-representative: paged-KV decode)

| iter | hypothesis | change | before -> after | verdict |
|---|---|---|---|---|
| B1 | dynamic_update_slice on the model-sharded within-page dim makes XLA all-gather the whole KV pool per layer (~1 GiB/link/block) | append = dynamic-slice/update on the UNSHARDED page axis only; masked slot write inside the page | llama-1b decode coll 2.1e10 -> 3.6e8 B/dev (59x), 418 -> 7.1 ms | **confirmed** |
| B2 | the gather through the block table copies the whole pool (reshape merging unsharded-major x sharded-minor dims cannot keep sharding) | zero-copy attention: attend in PHYSICAL page order; translate only metadata (inverse-table -> per-page positions) — the paper's map-don't-copy insight applied inside the kernel | qwen2 decode: coll 3.19e10 -> 1.84e9 B (17x, 637 -> 37 ms); bytes 1.23e11 -> 2.80e10 (4.4x, 150 -> 34 ms); flops 5.5x down | **confirmed** |

Cell B net: decode step bound improved ~17x; dominant term now memory
(one pool read + fp32 score blocks), within ~4x of the pool-read lower
bound (2 x KV bytes/device = 7.5 ms).

### Cell C — kimi-k2-1t-a32b train_4k (most collective-bound)

| iter | hypothesis | change | before -> after | verdict |
|---|---|---|---|---|
| C1 | remat=full re-gathers FSDP expert weights during bwd recompute; saving dot outputs avoids one gather wave | remat policy full -> dots_with_no_batch_dims | per-block coll 56.4 -> 52.9 GiB (-6.2%) | **refuted** (XLA already CSEs recompute gathers; the traffic is inherent FSDP weight movement) |
| C2 | gathers move f32 (2x bf16) | inspect HLO dtype mix | 100% of all-gather bytes are f32 — but this is the CPU backend promoting bf16 dots; TPU gathers bf16 natively. Documented as a 2x systematic overstatement, not a code change | backend artifact |

Cell C conclusion (negative result, quantified): kimi train on ONE v5e pod
is inherently FSDP-gather-bound — per-device per-block weight traffic
(~2 GiB bf16 x fwd+bwd) puts the collective term within ~2x of the ZeRO-3
lower bound. The structural fixes are more chips (>=4 pods, where the
fsdp axis shrinks per-device traffic) or resident expert weights via
pure EP x TP at larger scale — matching why nobody trains 1T models on
256 chips. The dry-run quantifies exactly that.

### Beyond-paper optimizations (summary)

* ZeRO-R activation partitioning (A4) — not in the paper, standard at pod
  scale, 2.7x peak-memory win.
* Flash custom-VJP (A1) — the TPU-native replacement for the cluster's
  double-buffered DMA loop, with exact backward.
* Zero-copy physical-order paged attention (B2) — extends the paper's
  zero-copy thesis INTO the kernel: translate block tables, never the data.
* GPipe pipeline parallelism over a stage axis (launch/pipeline.py),
  int8 error-feedback gradient compression, async sharded checkpoints with
  elastic restore — the 1000+-node toolkit, all tested on CPU.
"""


def section_train(out):
    log = pathlib.Path("results/train_100m_clean.log")
    out.append("\n## §Training run — ~100M params, synthetic stream\n")
    if log.exists():
        lines = [l for l in log.read_text().splitlines() if "loss" in l]
        if lines:
            out.append("`examples/train_100m.py` (8L x 768d llama-family, "
                       "vocab 32768 tied, AdamW + cosine; 1/sqrt(2L) "
                       "residual-init damping — without it the tied-table "
                       "gradient explodes to ~2.6e6 and learning stalls):\n```")
            out.extend(lines)
            out.append("```")
    out.append("\nFault-tolerance demo (tests/test_system.py): failure "
               "injected at step 7 -> automatic restore from step-5 "
               "checkpoint -> run completes; elastic restore re-places "
               "leaves under new shardings.\n")


def main():
    out = ["# EXPERIMENTS", ""]
    section_paper_validation(out)
    section_dryrun(out)
    section_roofline(out)
    out.append(PERF)
    section_train(out)
    print("\n".join(out))


if __name__ == "__main__":
    main()
