"""Benchmark E1 — paper Table II: kernel cycles x DRAM latency x config.

Runs the calibrated simulator for all 36 cells and reports per-cell error
against the published numbers, plus the §IV-B headline claims.
"""
from __future__ import annotations

import time
from typing import List

from repro.core.simulator.paper_targets import CLAIMS, TABLE2
from repro.core.simulator.run import simulate_kernel

LATS = (200, 600, 1000)


def run() -> List[str]:
    rows = []
    errs = []
    t0 = time.perf_counter()
    for kernel, tgt in TABLE2.items():
        for config in ("baseline", "iommu", "iommu_llc"):
            for lat in LATS:
                sim = simulate_kernel(kernel, config, lat).total
                ref = tgt[config][lat]
                err = (sim - ref) / ref
                errs.append(abs(err))
                rows.append(f"table2.{kernel}.{config}.{lat},"
                            f"{sim:.4g},paper={ref:.4g} err={100*err:+.1f}%")
    us = (time.perf_counter() - t0) * 1e6 / len(errs)
    mean_err = 100 * sum(errs) / len(errs)
    max_err = 100 * max(errs)
    rows.append(f"table2.summary,{us:.1f},mean|err|={mean_err:.2f}% "
                f"max|err|={max_err:.2f}% (36 cells)")

    g = {lat: (simulate_kernel("gemm", "iommu", lat).total
               / simulate_kernel("gemm", "baseline", lat).total - 1) * 100
         for lat in LATS}
    rows.append(f"table2.claim.gemm_overhead,{g[200]:.1f},"
                f"paper={CLAIMS['gemm_overhead_low_pct']}% (low latency)")
    rows.append(f"table2.claim.gemm_overhead_hi,{g[1000]:.1f},"
                f"paper={CLAIMS['gemm_overhead_high_pct']}% (high latency)")
    worst = max((simulate_kernel(k, "iommu_llc", lat).total
                 / simulate_kernel(k, "baseline", lat).total - 1) * 100
                for k in TABLE2 for lat in LATS)
    rows.append(f"table2.claim.llc_overhead_max,{worst:.2f},"
                f"paper=<{CLAIMS['llc_overhead_max_pct']}% (all kernels)")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
