"""TPU v5e hardware constants for the roofline model (task-specified)."""

PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link

CHIP_HBM_BYTES = 16 * 2**30   # v5e HBM capacity (for fits/doesn't-fit notes)
