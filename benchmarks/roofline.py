"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh)
from the dry-run's compiled artifacts.

  compute    = HLO_FLOPs / peak_FLOPs            (per chip; cost_analysis is
                                                  the per-device program)
  memory     = HLO_bytes / HBM_bw
  collective = collective_link_bytes / link_bw   (all-reduce counted 2x)

Scan correction (DESIGN.md §6): XLA counts a while body ONCE, so totals are
reconstructed from the unrolled 1-block / 2-block variants:
  total = U1 + (n_blocks - 1) * (U2 - U1)
MODEL_FLOPS uses 6*N*D (train) / 2*N_active*tokens (serve) with N from the
analytic parameter count.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

from benchmarks.hw import CHIP_HBM_BYTES, HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.configs import (SHAPES, get_config, model_active_params,
                           model_params)

ART_DIR = pathlib.Path("results/dryrun")


def _coll_bytes(colls: Dict) -> float:
    return sum((2 if k == "all-reduce" else 1) * v["bytes"]
               for k, v in colls.items())


def corrected_costs(art: dict) -> Optional[Dict[str, float]]:
    """Block-differenced totals; falls back to raw program costs (marked)."""
    if "unrolled_1block" not in art:
        return None
    u1, u2 = art["unrolled_1block"], art["unrolled_2block"]
    n = art["n_blocks"]
    out = {}
    for key, get in (
            ("flops", lambda a: a["cost"]["flops"]),
            ("bytes", lambda a: a["cost"]["bytes_accessed"]),
            ("coll", lambda a: _coll_bytes(a["collectives"]))):
        per_block = get(u2) - get(u1)
        out[key] = get(u1) + (n - 1) * per_block
    return out


def model_flops_per_device(arch: str, shape_name: str, n_devices: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = model_active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:                                   # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / n_devices


def analyze_cell(art: dict) -> Optional[dict]:
    if art.get("skipped") or art.get("error"):
        return None
    arch = art["arch"]
    shape_name = art["shape"]["name"]
    n_dev = 1
    for s in art["mesh"]["shape"]:
        n_dev *= s
    cc = corrected_costs(art)
    raw = {"flops": art["cost"]["flops"],
           "bytes": art["cost"]["bytes_accessed"],
           "coll": _coll_bytes(art["collectives"])}
    costs = cc or raw
    t_compute = costs["flops"] / PEAK_FLOPS_BF16
    t_memory = costs["bytes"] / HBM_BW
    t_coll = costs["coll"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_per_device(arch, shape_name, n_dev)
    peak_gib = art["memory"]["peak_bytes_per_device"] / 2**30
    step_s = max(terms.values())
    mfu_bound = (mf / PEAK_FLOPS_BF16) / step_s if step_s > 0 else 0.0
    note = {
        "compute": "reduce non-useful FLOPs (remat policy, causal-skip "
                   "attention kernel, fused epilogues)",
        "memory": "raise arithmetic intensity (larger per-step tiles, "
                  "fuse elementwise chains, shrink fp32 temporaries)",
        "collective": "reshard to cut all-gather/all-reduce volume "
                      "(activation-sharded remat, hierarchical reduction, "
                      "int8-compressed grads)",
    }[bottleneck]
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in art["mesh"]["shape"]),
        "corrected": cc is not None,
        "flops_dev": costs["flops"], "bytes_dev": costs["bytes"],
        "coll_bytes_dev": costs["coll"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops_dev": mf,
        "useful_flops_ratio": mf / costs["flops"] if costs["flops"] else 0.0,
        "roofline_fraction": min(mfu_bound, 1.0),
        "peak_gib_per_dev": peak_gib,
        "fits_v5e": peak_gib * 2**30 <= CHIP_HBM_BYTES,
        "note": note,
    }


def load_all(pod: str = "pod1") -> List[dict]:
    out = []
    for p in sorted(ART_DIR.glob(f"*__{pod}.json")):
        art = json.loads(p.read_text())
        r = analyze_cell(art)
        if r is not None:
            out.append(r)
    return out


def run() -> List[str]:
    rows = []
    cells = load_all("pod1")
    for c in cells:
        rows.append(
            f"roofline.{c['arch']}.{c['shape']},{c['roofline_fraction']*100:.1f},"
            f"bottleneck={c['bottleneck']} "
            f"tc={c['t_compute_s']*1e3:.2f}ms tm={c['t_memory_s']*1e3:.2f}ms "
            f"tl={c['t_collective_s']*1e3:.2f}ms "
            f"useful={c['useful_flops_ratio']*100:.0f}% "
            f"peak={c['peak_gib_per_dev']:.1f}GiB"
            f"{'' if c['fits_v5e'] else ' OVER-HBM'}"
            f"{'' if c['corrected'] else ' UNCORRECTED'}")
    if not cells:
        rows.append("roofline.skipped,0,no dry-run artifacts in results/dryrun")
    return rows


def markdown_table(pod: str = "pod1") -> str:
    cells = load_all(pod)
    hdr = ("| arch | shape | compute s | memory s | collective s | bottleneck "
           "| MODEL/HLO | roofline frac | GiB/dev | fits v5e |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for c in cells:
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['t_compute_s']:.3e} | "
            f"{c['t_memory_s']:.3e} | {c['t_collective_s']:.3e} | "
            f"{c['bottleneck']} | {c['useful_flops_ratio']:.2f} | "
            f"{c['roofline_fraction']*100:.1f}% | "
            f"{c['peak_gib_per_dev']:.2f} | "
            f"{'yes' if c['fits_v5e'] else 'NO'} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print("\n".join(run()))
