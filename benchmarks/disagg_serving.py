"""Disaggregated prefill/decode A/B on the bursty Poisson workload:
colocated-continuous vs disagg-copy vs disagg-share at the SAME
(oversubscribed) page pool and the SAME total slot width.

The question is the paper's zero-copy-offload claim at cross-worker
scale: when a finished prefill's KV hands off to the decode worker, what
moves? ``copy`` stages the full KV payload (device-side batched page
copy); ``share`` re-maps the same physical pages under the decode
worker's ASID and moves only int32 table entries. Both price the
hand-off's per-page translations through a transfer IOMMU configured as
the paper's hardware — a 4-entry IOTLB over ``Sv39Walk(llc=False)`` — so
the report carries transfer bytes AND transfer PTW cycles side by side.

Reported rows (``name,value,derived`` CSV):

  disagg_serving.bit_identical          share AND copy outputs vs the
                                        colocated continuous engine
  disagg_serving.<mode>.transfer_bytes  payload + table bytes moved
  disagg_serving.transfer_bytes_ratio   copy / share (the SVA payoff)
  disagg_serving.<mode>.transfer_ptw_cycles
                                        modeled remote-DMA walk cost
  disagg_serving.<mode>.ttfdt           mean steps from submit to first
                                        DECODE-step token (the transfer
                                        queue's latency cost)

Run directly (``--dry-run`` for the CI smoke sizes) or via
``python -m benchmarks.run --only disagg``.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.paged_serving import (_BURST_POOL, _bursty_workload,
                                      _cfg_params)
from repro.configs import get_config, reduce_for_smoke
from repro.configs.paper_soc import PaperSoCConfig
from repro.core.serving.disagg import DisaggEngine
from repro.core.serving.engine import ServingEngine
from repro.core.sva.iommu import IOMMU, Sv39Walk, TLBConfig


def _xfer_iommu(soc: PaperSoCConfig) -> IOMMU:
    """The transfer fabric's translation hardware: the paper's 4-entry
    IOTLB in front of a no-LLC Sv39 page-table walk — the design point
    where translation cost is most exposed, so the remote-DMA pricing is
    a worst case, not a rounding error."""
    return IOMMU(walk_model=Sv39Walk(llc=False),
                 tlb=TLBConfig(soc.iotlb_entries, "lru"))


def _drive(eng, prompts, maxtoks, arrivals):
    """Clock-driven arrival loop (the engine never sees the future).
    Returns (outputs, finished requests in submission order, stats)."""
    finished = {}
    rids = [None] * len(prompts)
    order = sorted(range(len(prompts)), key=lambda j: arrivals[j])
    i, clock = 0, 0
    while i < len(order) or eng.has_work:
        while i < len(order) and arrivals[order[i]] <= clock:
            j = order[i]
            rids[j] = eng.submit(prompts[j], max_tokens=maxtoks[j])
            i += 1
        if eng.has_work:
            eng.step(finished)
        clock += 1
    reqs = [finished[r] for r in rids]
    return [r.out_tokens for r in reqs], reqs, eng.stats()


def _ttfdt(reqs) -> float:
    """Mean steps from submission to the first token a DECODE step
    produced. In the disaggregated engine this spans admission wait +
    chunked prefill + the transfer queue; a request that finished at
    prefill (budget exhausted before any decode) is excluded."""
    deltas = [r.first_decode_step - r.submitted_step for r in reqs
              if r.first_decode_step is not None]
    return float(np.mean(deltas)) if deltas else 0.0


def run(dry_run: bool = False) -> List[str]:
    n_req = 4 if dry_run else 6
    soc = PaperSoCConfig()
    vocab = reduce_for_smoke(get_config("llama3.2-1b")).vocab_size
    prompts, maxtoks, arrivals = _bursty_workload(vocab, n_req)
    cfg, params = _cfg_params()

    # Colocated reference: 4 slots, every slot admits AND decodes.
    ref_eng = ServingEngine(cfg, params, n_slots=4, max_len=64, page_size=8,
                            scheduler="continuous", pool_pages=_BURST_POOL)
    ref_outs, ref_reqs, _ = _drive(ref_eng, prompts, maxtoks, arrivals)

    rows = []
    bytes_moved, identical = {}, True
    for mode in ("copy", "share"):
        eng = DisaggEngine(cfg, params, n_prefill_slots=2, n_decode_slots=2,
                           max_len=64, page_size=8, disagg_mode=mode,
                           pool_pages=_BURST_POOL, xfer_iommu=_xfer_iommu(soc))
        outs, reqs, s = _drive(eng, prompts, maxtoks, arrivals)
        identical = identical and outs == ref_outs
        t = s["transfer"]
        d = s["disagg"]
        bytes_moved[mode] = t["payload_bytes"] + t["table_bytes"]
        rows.append(
            f"disagg_serving.{mode}.transfer_bytes,{bytes_moved[mode]},"
            f"payload={t['payload_bytes']} table={t['table_bytes']} over "
            f"{t['transfers']} transfers "
            f"(pages copied={t['pages_copied']} shared={t['pages_shared']}; "
            f"deferred={d['deferred']} cancelled={d['cancelled']})")
        rows.append(
            f"disagg_serving.{mode}.transfer_ptw_cycles,"
            f"{t['ptw_cycles']:.0f},remote-DMA translation cost under a "
            f"{soc.iotlb_entries}-entry IOTLB + Sv39Walk(llc=False): "
            f"tlb_hits={t['tlb_hits']} tlb_misses={t['tlb_misses']}")
        rows.append(
            f"disagg_serving.{mode}.ttfdt,{_ttfdt(reqs):.1f},"
            f"mean steps submit -> first decode token "
            f"(colocated: {_ttfdt(ref_reqs):.1f}; "
            f"preemptions={s['sched']['preemptions']})")
    rows.append(
        f"disagg_serving.transfer_bytes_ratio,"
        f"{bytes_moved['copy'] / max(bytes_moved['share'], 1):.0f},"
        f"x fewer bytes moved by zero-copy ASID re-attachment vs staging "
        f"the KV (share={bytes_moved['share']} copy={bytes_moved['copy']}; "
        f"paper's table-entries-vs-payload argument at cross-worker scale)")
    rows.append(
        f"disagg_serving.bit_identical,{identical},"
        f"disagg-share AND disagg-copy outputs vs the colocated continuous "
        f"engine at equal total width (migration never changes tokens)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(
        description="Disaggregated prefill/decode serving A/B: colocated "
                    "vs disagg-copy vs disagg-share on the bursty Poisson "
                    "workload, with IOMMU-priced remote-DMA KV transfer.",
        epilog="Methodology and CSV columns: benchmarks/README.md; design "
               "notes: ARCHITECTURE.md 'Disaggregated serving'.")
    ap.add_argument("--dry-run", action="store_true",
                    help="minimal sizes (CI smoke path)")
    args = ap.parse_args()
    print("\n".join(run(dry_run=args.dry_run)))
