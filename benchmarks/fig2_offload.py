"""Benchmark E2 — paper Fig. 2: axpy offload breakdown (host / copy-based /
zero-copy) and copy-vs-map scaling with input size."""
from __future__ import annotations

from typing import List

from repro.core.simulator.paper_targets import CLAIMS
from repro.core.simulator.run import (host_copy_cycles, host_map_cycles,
                                      offload_breakdown)


def run() -> List[str]:
    rows = []
    for mode in ("host", "copy", "zero_copy"):
        b = offload_breakdown(mode, 32768, 200)
        rows.append(f"fig2.breakdown.{mode},{b.total:.0f},"
                    f"xfer={b.xfer:.0f} offload={b.offload:.0f} "
                    f"compute={b.compute:.0f} (host cycles)")
    copy_t = offload_breakdown("copy", 32768, 200).total
    zc_t = offload_breakdown("zero_copy", 32768, 200).total
    speedup = 100 * (1 - zc_t / copy_t)
    rows.append(f"fig2.claim.zero_copy_speedup,{speedup:.1f},"
                f"paper={CLAIMS['zero_copy_speedup_pct']}%")
    # right panel: copy vs map time with increasing input size
    for kib in (64, 128, 256, 384, 512, 1024):
        n = kib * 1024
        rows.append(f"fig2.scaling.copy.{kib}KiB,{host_copy_cycles(n, 200):.0f},")
        rows.append(f"fig2.scaling.map.{kib}KiB,{host_map_cycles(n, 200):.0f},")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
